//! Heterogeneous workload stress (the paper's Experiment 3B scenario):
//! tasks of 1–10 s with 1–4 CPUs and 0–8 GPUs on multi-node Kubernetes
//! clusters plus an HPC pilot — a "worst case" for broker overhead.
//!
//! ```bash
//! cargo run --release --example hetero_workload
//! ```

use hydra::api::task::Payload;
use hydra::api::{ResourceRequest, TaskDescription};
use hydra::broker::{BrokerPolicy, Hydra, PartitionModel};
use hydra::sim::provider::ProviderId;
use hydra::util::prng::Prng;
use hydra::util::fmt_secs;

fn hetero_tasks(n: usize, seed: u64) -> Vec<TaskDescription> {
    let mut rng = Prng::new(seed);
    (0..n)
        .map(|i| {
            let dur = rng.range_f64(1.0, 10.0);
            let cpus = rng.range_u64(1, 5) as u32;
            let gpus = rng.range_u64(0, 9) as u32 / 2; // 0..4, cluster cap 8
            if rng.bool_with_p(0.5) {
                TaskDescription::container(format!("con-{i}"), "hydra/stress:latest")
                    .with_cpus(cpus)
                    .with_gpus(gpus)
                    .with_payload(Payload::Sleep(dur))
            } else {
                TaskDescription::executable(format!("exe-{i}"), "stress")
                    .with_cpus(cpus)
                    .with_payload(Payload::Sleep(dur))
            }
        })
        .collect()
}

fn main() -> Result<(), Box<dyn std::error::Error>> {
    println!("{:>6} {:>12} {:>12} {:>12} {:>10}", "NODES", "OVH", "TH (t/s)", "TTX", "TASKS");
    for nodes in [2u32, 4, 6] {
        let mut b = Hydra::builder().partition_model(PartitionModel::Scpp).seed(17);
        for p in [ProviderId::Jetstream2, ProviderId::Azure] {
            b = b.simulated_provider(p).resource(
                ResourceRequest::kubernetes(p, nodes, 16).with_gpus_per_node(8),
            );
        }
        b = b
            .simulated_provider(ProviderId::Bridges2)
            .resource(ResourceRequest::pilot(ProviderId::Bridges2, 1));
        let hydra = b.build()?;
        let run = hydra.submit(hetero_tasks(10_240, 3), &BrokerPolicy::ByTaskKind)?;
        println!(
            "{:>6} {:>12} {:>12.0} {:>12} {:>10}",
            nodes,
            fmt_secs(run.aggregate.ovh_s),
            run.aggregate.th_tps,
            fmt_secs(run.aggregate.ttx_s),
            run.aggregate.tasks
        );
    }
    println!("\nExp 3B shape: OVH/TH ~invariant in node count; TTX improves with nodes.");
    Ok(())
}
