//! Quickstart: broker 1,000 container tasks onto one simulated cloud.
//!
//! ```bash
//! cargo run --release --example quickstart
//! ```
//!
//! Shows the four API classes of the paper's §3.2 in ~30 lines: Provider
//! (simulated credentials), Resource (a 16-vCPU Kubernetes node on
//! Jetstream2), Task (noop containers), and the Service proxy that brokers
//! them — then prints the paper's metrics (OVH, TH, TPT).

use hydra::api::{ResourceRequest, TaskDescription};
use hydra::broker::{BrokerPolicy, Hydra, PartitionModel};
use hydra::sim::provider::ProviderId;
use hydra::util::fmt_secs;

fn main() -> Result<(), Box<dyn std::error::Error>> {
    // Provider + Resource: one Kubernetes node with 16 vCPUs on Jetstream2.
    let hydra = Hydra::builder()
        .simulated_provider(ProviderId::Jetstream2)
        .resource(ResourceRequest::kubernetes(ProviderId::Jetstream2, 1, 16))
        .partition_model(PartitionModel::Mcpp { max_cpp: 16 })
        .seed(42)
        .build()?;

    // Task: 1,000 noop containers (the paper's Experiment-1 style load).
    let tasks: Vec<TaskDescription> = (0..1000)
        .map(|i| TaskDescription::container(format!("noop-{i}"), "hydra/noop:latest"))
        .collect();

    // Service: broker, trace, report.
    let run = hydra.submit(tasks, &BrokerPolicy::RoundRobin)?;
    let m = &run.per_provider()[0];
    println!("brokered {} tasks as {} pods on {}", m.tasks, m.pods, m.provider);
    println!("  OVH (broker overhead)  : {}", fmt_secs(m.ovh.total_s()));
    println!("  TH  (broker throughput): {:.0} tasks/s", m.throughput_tps());
    println!("  TPT (platform time)    : {}", fmt_secs(m.tpt_s));
    assert!(hydra.registry().all_final());
    println!("all tasks reached a final state; trace has {} events",
             hydra.registry().trace_len());
    Ok(())
}
