"""L2 correctness: FACTS step functions (model.py) — shapes, invariants,
and agreement between the unrolled linear algebra and numpy's LAPACK."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest
from hypothesis import given, settings, strategies as st

from compile import model as M
from compile.kernels import ref

SHORT = settings(max_examples=20, deadline=None)
Q = len(M.QUANTILES)


def synth_records(seed, B, T):
    """Synthetic (temps, rates) with a known ground-truth a, T0."""
    rng = np.random.default_rng(seed)
    a_true = rng.uniform(1.0, 4.0, size=(B, 1))
    T0_true = rng.uniform(-0.5, 0.5, size=(B, 1))
    temps = np.linspace(0.0, 1.5, T)[None, :] + 0.05 * rng.standard_normal((B, T))
    rates = a_true * (temps - T0_true) + 0.01 * rng.standard_normal((B, T))
    return (jnp.asarray(temps, jnp.float32), jnp.asarray(rates, jnp.float32),
            a_true[:, 0], T0_true[:, 0])


class TestPreprocess:
    @SHORT
    @given(B=st.integers(1, 12), T=st.integers(21, 96), seed=st.integers(0, 999))
    def test_shapes_and_columns(self, B, T, seed):
        temps, rates, _, _ = synth_records(seed, B, T)
        X4, X2, y, tref = M.facts_preprocess(temps, rates)
        assert X4.shape == (B, T, 4) and X2.shape == (B, T, 2)
        assert y.shape == (B, T) and tref.shape == (B,)
        np.testing.assert_allclose(X4[..., 0], 1.0)
        np.testing.assert_allclose(X2[..., 1], X4[..., 1], rtol=1e-6)
        np.testing.assert_allclose(X4[..., 2], X4[..., 1] ** 2, rtol=1e-4, atol=1e-5)

    def test_anomaly_baseline_window(self):
        temps = jnp.ones((2, 40)) * 3.0
        rates = jnp.zeros((2, 40))
        X4, _, _, tref = M.facts_preprocess(temps, rates)
        np.testing.assert_allclose(tref, 3.0, rtol=1e-6)
        np.testing.assert_allclose(X4[..., 1], 0.0, atol=1e-6)


class TestFit:
    @SHORT
    @given(B=st.integers(1, 10), T=st.integers(16, 80), K=st.sampled_from([2, 4]),
           seed=st.integers(0, 2**31 - 1))
    def test_matches_numpy_lstsq(self, B, T, K, seed):
        key = jax.random.PRNGKey(seed)
        kx, ky = jax.random.split(key)
        X = jax.random.normal(kx, (B, T, K))
        y = jax.random.normal(ky, (B, T))
        theta, sigma2, A = M.facts_fit(X, y)
        for b in range(B):
            Xa, ya = np.asarray(X[b], np.float64), np.asarray(y[b], np.float64)
            ref_th = np.linalg.solve(Xa.T @ Xa + M.RIDGE_LAM * np.eye(K), Xa.T @ ya)
            np.testing.assert_allclose(theta[b], ref_th, rtol=2e-3, atol=2e-3)
        assert (np.asarray(sigma2) >= 0).all()
        np.testing.assert_allclose(A, np.swapaxes(np.asarray(A), 1, 2), rtol=1e-5)

    def test_recovers_true_parameters(self):
        temps, rates, a_true, T0_true = synth_records(5, 6, 64)
        _, X2, y, tref = M.facts_preprocess(temps, rates)
        theta, sigma2, _ = M.facts_fit(X2, y)
        a_hat = np.asarray(theta[:, 1])
        # rate = c + a*Tn with Tn = T - tref  =>  T0 = tref - c/a
        T0_hat = np.asarray(tref) - np.asarray(theta[:, 0]) / a_hat
        np.testing.assert_allclose(a_hat, a_true, rtol=0.15)
        np.testing.assert_allclose(T0_hat, T0_true, atol=0.2)
        assert (np.asarray(sigma2) < 0.05).all()

    def test_perfect_fit_zero_residual(self):
        X = jnp.broadcast_to(jnp.stack(
            [jnp.ones(32), jnp.linspace(0, 1, 32)], -1), (3, 32, 2))
        theta_true = jnp.array([[1.0, 2.0]] * 3)
        y = jnp.einsum("btk,bk->bt", X, theta_true)
        theta, sigma2, _ = M.facts_fit(X, y)
        np.testing.assert_allclose(theta, theta_true, rtol=1e-3, atol=1e-3)
        assert (np.asarray(sigma2) < 1e-5).all()


class TestProject:
    def _fitted(self, seed=7, B=4, T=64):
        temps, rates, _, _ = synth_records(seed, B, T)
        X4, X2, y, tref = M.facts_preprocess(temps, rates)
        return X4, X2, y, tref

    @SHORT
    @given(Mm=st.integers(1, 12), Y=st.integers(2, 48), seed=st.integers(0, 999))
    def test_se_shapes_and_ordered_quantiles(self, Mm, Y, seed):
        _, X2, y, _ = self._fitted(seed)
        theta, s2, A = M.facts_fit(X2, y)
        eps = jax.random.normal(jax.random.PRNGKey(seed), (4, Mm, 2))
        temps_fut = jnp.linspace(0.5, 2.5, Y)
        q, mean = M.facts_project_se(theta, s2, A, eps, temps_fut)
        assert q.shape == (Q, Y) and mean.shape == (Y,)
        assert (np.diff(np.asarray(q), axis=0) >= -1e-4).all(), "quantiles must be ordered"

    def test_zero_eps_collapses_to_point_estimate(self):
        """With eps = 0 every sample equals theta-hat: the MC spread vanishes,
        so the median and the ensemble mean are invariant to the number of
        (identical) samples per site. Outer quantiles shift only by the
        interpolation positions of the duplicated sample set, so we compare
        the duplication-invariant statistics."""
        _, X2, y, _ = self._fitted()
        theta, s2, A = M.facts_fit(X2, y)
        tf = jnp.linspace(0.5, 2.0, 10)
        q6, mean6 = M.facts_project_se(theta, s2, A, jnp.zeros((4, 6, 2)), tf)
        q2, mean2 = M.facts_project_se(theta, s2, A, jnp.zeros((4, 2, 2)), tf)
        mid = Q // 2
        np.testing.assert_allclose(q6[mid], q2[mid], rtol=1e-5, atol=1e-5)
        np.testing.assert_allclose(mean6, mean2, rtol=1e-5, atol=1e-5)

    def test_posterior_spread_grows_with_sigma(self):
        _, X2, y, _ = self._fitted()
        theta, s2, A = M.facts_fit(X2, y)
        eps = jax.random.normal(jax.random.PRNGKey(0), (4, 32, 2))
        tf = jnp.linspace(0.5, 2.0, 12)
        q_lo, _ = M.facts_project_se(theta, s2, A, eps, tf)
        q_hi, _ = M.facts_project_se(theta, s2 * 100.0, A, eps, tf)
        assert float(q_hi[-1, -1] - q_hi[0, -1]) > float(q_lo[-1, -1] - q_lo[0, -1])

    @SHORT
    @given(Mm=st.integers(1, 8), Y=st.integers(2, 32), seed=st.integers(0, 999))
    def test_poly_shapes(self, Mm, Y, seed):
        X4, _, y, _ = self._fitted(seed)
        theta, s2, A = M.facts_fit(X4, y)
        eps = jax.random.normal(jax.random.PRNGKey(seed), (4, Mm, 4))
        tf = jnp.linspace(0.5, 2.5, Y)
        phi = jnp.stack([jnp.ones(Y), tf, tf * tf, jnp.linspace(0, 1, Y)], -1)
        q, mean = M.facts_project_poly(theta, s2, A, eps, phi)
        assert q.shape == (Q, Y) and mean.shape == (Y,)
        assert (np.diff(np.asarray(q), axis=0) >= -1e-4).all()


class TestPostprocess:
    def test_weighted_combination(self):
        q1 = jnp.ones((Q, 8)) * 1.0
        q2 = jnp.ones((Q, 8)) * 3.0
        comb, env, tot = M.facts_postprocess(jnp.stack([q1, q2]), jnp.array([1.0, 1.0]))
        np.testing.assert_allclose(comb, 2.0, rtol=1e-6)
        np.testing.assert_allclose(env[0], 1.0)
        np.testing.assert_allclose(env[1], 3.0)
        np.testing.assert_allclose(tot, 2.0)

    def test_weights_renormalized(self):
        q = jnp.ones((2, Q, 4))
        c1, _, _ = M.facts_postprocess(q, jnp.array([2.0, 2.0]))
        c2, _, _ = M.facts_postprocess(q, jnp.array([0.5, 0.5]))
        np.testing.assert_allclose(c1, c2, rtol=1e-6)

    def test_envelope_contains_combined(self):
        key = jax.random.PRNGKey(3)
        quants = jnp.sort(jax.random.normal(key, (2, Q, 6)), axis=1)
        comb, env, _ = M.facts_postprocess(quants, jnp.array([0.3, 0.7]))
        assert (np.asarray(comb[0]) >= np.asarray(env[0]) - 1e-5).all()
        assert (np.asarray(comb[-1]) <= np.asarray(env[1]) + 1e-5).all()


class TestUnrolledLinalg:
    @SHORT
    @given(B=st.integers(1, 8), K=st.sampled_from([2, 3, 4, 5]),
           seed=st.integers(0, 2**31 - 1))
    def test_cholesky_solve_vs_numpy(self, B, K, seed):
        key = jax.random.PRNGKey(seed)
        R = jax.random.normal(key, (B, K, K))
        G = jnp.einsum("bik,bjk->bij", R, R) + 0.5 * jnp.eye(K)[None]
        m = jax.random.normal(key, (B, K))
        th = ref.cholesky_solve_small_ref(G, m, 1e-3)
        want = np.linalg.solve(np.asarray(G, np.float64) + 1e-3 * np.eye(K),
                               np.asarray(m, np.float64)[..., None])[..., 0]
        np.testing.assert_allclose(th, want, rtol=2e-2, atol=2e-2)
