"""AOT path: variant coverage, manifest consistency, HLO-text validity.

These tests gate the artifact contract between the Python compile path and
the Rust runtime (rust/src/runtime parses the same manifest)."""

import json
import os
import subprocess
import sys
import tempfile

import jax
import pytest

from compile import aot, model as M


@pytest.fixture(scope="module")
def built(tmp_path_factory):
    out = tmp_path_factory.mktemp("artifacts")
    env = dict(os.environ)
    subprocess.run(
        [sys.executable, "-m", "compile.aot", "--out-dir", str(out)],
        check=True, cwd=os.path.dirname(os.path.dirname(__file__)), env=env,
    )
    return out


def test_variant_enumeration_covers_all_steps_and_sizes():
    names = [name for name, *_ in aot.variants()]
    assert len(names) == len(set(names)) == 18
    for size in aot.SIZES:
        for step in ["preprocess", "fit_k2", "fit_k4", "project_se",
                     "project_poly", "postprocess"]:
            assert f"{step}_{size}" in names


def test_manifest_matches_files(built):
    manifest = json.load(open(built / "manifest.json"))
    assert manifest["format"] == "hlo-text-v1"
    assert manifest["quantiles"] == list(M.QUANTILES)
    assert len(manifest["artifacts"]) == 18
    for art in manifest["artifacts"]:
        path = built / art["file"]
        assert path.exists(), art["file"]
        text = path.read_text()
        # HLO text sanity: module header and an entry computation.
        assert text.startswith("HloModule"), art["file"]
        assert "ENTRY" in text, art["file"]
        for io in art["inputs"] + art["outputs"]:
            assert io["dtype"] == "f32"
            assert all(isinstance(d, int) and d > 0 for d in io["shape"])


def test_manifest_shapes_match_eval_shape(built):
    manifest = json.load(open(built / "manifest.json"))
    by_name = {a["name"]: a for a in manifest["artifacts"]}
    for name, fn, in_specs, out_names in aot.variants():
        art = by_name[name]
        assert [list(s.shape) for s in in_specs] == [i["shape"] for i in art["inputs"]]
        outs = jax.tree_util.tree_leaves(jax.eval_shape(fn, *in_specs))
        assert [list(o.shape) for o in outs] == [o["shape"] for o in art["outputs"]]
        assert len(out_names) == len(art["outputs"])


def test_hlo_contains_no_lapack_custom_calls(built):
    """The Rust CPU PJRT client can only run core HLO ops: the unrolled
    Cholesky must not have lowered to LAPACK custom-calls."""
    for f in built.glob("*.hlo.txt"):
        text = f.read_text()
        assert "lapack" not in text.lower(), f.name
        assert "getrf" not in text, f.name
        assert "potrf" not in text, f.name


def test_filter_flag_builds_subset(tmp_path):
    subprocess.run(
        [sys.executable, "-m", "compile.aot", "--out-dir", str(tmp_path),
         "--only", "fit_k2_small"],
        check=True, cwd=os.path.dirname(os.path.dirname(__file__)),
    )
    manifest = json.load(open(tmp_path / "manifest.json"))
    assert [a["name"] for a in manifest["artifacts"]] == ["fit_k2_small"]
