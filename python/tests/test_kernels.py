"""L1 correctness: Pallas kernels vs the pure-jnp oracle (ref.py).

hypothesis sweeps shapes, dtypes and block sizes; assert_allclose against
the reference is the core correctness signal of the compile path.
"""

import jax
import jax.numpy as jnp
import numpy as np
import pytest
from hypothesis import given, settings, strategies as st

from compile.kernels import ref
from compile.kernels import sealevel as k

jax.config.update("jax_enable_x64", False)

SHORT = settings(max_examples=25, deadline=None)


def rng_arrays(seed, *shapes, dtype=jnp.float32):
    key = jax.random.PRNGKey(seed)
    keys = jax.random.split(key, len(shapes))
    return [jax.random.normal(kk, s, dtype=dtype) for kk, s in zip(keys, shapes)]


# ---------------------------------------------------------------------------
# batched_gram
# ---------------------------------------------------------------------------

class TestBatchedGram:
    @SHORT
    @given(B=st.integers(1, 24), T=st.integers(2, 96), K=st.integers(1, 8),
           seed=st.integers(0, 2**31 - 1))
    def test_matches_ref(self, B, T, K, seed):
        X, = rng_arrays(seed, (B, T, K))
        y, = rng_arrays(seed + 1, (B, T))
        G, m = k.batched_gram(X, y)
        Gr, mr = ref.gram_ref(X, y)
        np.testing.assert_allclose(G, Gr, rtol=2e-5, atol=1e-5)
        np.testing.assert_allclose(m, mr, rtol=2e-5, atol=1e-5)

    @SHORT
    @given(bb=st.integers(1, 9), seed=st.integers(0, 1000))
    def test_block_size_invariance(self, bb, seed):
        """Result must not depend on the batch block size."""
        X, y = rng_arrays(seed, (7, 33, 3), (7, 33))
        G1, m1 = k.batched_gram(X, y, block_b=bb)
        G2, m2 = k.batched_gram(X, y, block_b=7)
        np.testing.assert_allclose(G1, G2, rtol=1e-6, atol=1e-6)
        np.testing.assert_allclose(m1, m2, rtol=1e-6, atol=1e-6)

    def test_gram_is_symmetric_psd(self):
        X, y = rng_arrays(3, (6, 40, 4), (6, 40))
        G, _ = k.batched_gram(X, y)
        np.testing.assert_allclose(G, np.swapaxes(G, 1, 2), rtol=1e-6)
        evals = np.linalg.eigvalsh(np.asarray(G))
        assert (evals > -1e-4).all()

    def test_bf16_inputs_accumulate_f32(self):
        X, y = rng_arrays(4, (4, 32, 4), (4, 32))
        G16, _ = k.batched_gram(X.astype(jnp.bfloat16), y.astype(jnp.bfloat16))
        Gr, _ = ref.gram_ref(X, y)
        assert G16.dtype == jnp.float32
        np.testing.assert_allclose(G16, Gr, rtol=5e-2, atol=5e-2)

    def test_empty_batch_rejected(self):
        with pytest.raises(Exception):
            k.batched_gram(jnp.zeros((0, 4, 2)), jnp.zeros((0, 4)))


# ---------------------------------------------------------------------------
# ensemble_project
# ---------------------------------------------------------------------------

class TestEnsembleProject:
    @SHORT
    @given(N=st.integers(1, 64), Y=st.integers(1, 80),
           dt=st.sampled_from([0.25, 0.5, 1.0]), seed=st.integers(0, 2**31 - 1))
    def test_matches_ref(self, N, Y, dt, seed):
        a, T0, temps = rng_arrays(seed, (N,), (N,), (Y,))
        S = k.ensemble_project(a, T0, temps, dt=dt)
        Sr = ref.project_ref(a, T0, temps, dt)
        np.testing.assert_allclose(S, Sr, rtol=2e-4, atol=1e-4)

    @SHORT
    @given(bn=st.sampled_from([8, 16, 24, 40]), seed=st.integers(0, 1000))
    def test_block_size_invariance(self, bn, seed):
        a, T0, temps = rng_arrays(seed, (37,), (37,), (21,))
        S1 = k.ensemble_project(a, T0, temps, block_n=bn)
        S2 = ref.project_ref(a, T0, temps, 1.0)
        np.testing.assert_allclose(S1, S2, rtol=1e-4, atol=1e-5)

    def test_zero_sensitivity_is_flat(self):
        temps, = rng_arrays(1, (12,))
        S = k.ensemble_project(jnp.zeros(9), jnp.ones(9), temps)
        np.testing.assert_allclose(S, 0.0, atol=1e-7)

    def test_constant_forcing_is_linear_in_time(self):
        """T == T0 + c forever => S[y] = a*c*(y+1)*dt exactly."""
        a = jnp.array([2.0]); T0 = jnp.array([1.0])
        temps = jnp.full((10,), 1.5)
        S = np.asarray(k.ensemble_project(a, T0, temps, dt=1.0))[0]
        np.testing.assert_allclose(S, 2.0 * 0.5 * np.arange(1, 11), rtol=1e-5)

    def test_trajectories_independent_across_members(self):
        """Changing member j must not affect member i."""
        a, T0, temps = rng_arrays(7, (16,), (16,), (8,))
        S1 = np.asarray(k.ensemble_project(a, T0, temps))
        a2 = a.at[5].set(99.0)
        S2 = np.asarray(k.ensemble_project(a2, T0, temps))
        np.testing.assert_allclose(np.delete(S1, 5, 0), np.delete(S2, 5, 0), rtol=1e-6)


# ---------------------------------------------------------------------------
# ensemble_project_poly
# ---------------------------------------------------------------------------

class TestEnsembleProjectPoly:
    @SHORT
    @given(N=st.integers(1, 48), Y=st.integers(1, 64), K=st.integers(1, 6),
           seed=st.integers(0, 2**31 - 1))
    def test_matches_ref(self, N, Y, K, seed):
        Th, Phi = rng_arrays(seed, (N, K), (Y, K))
        S = k.ensemble_project_poly(Th, Phi, dt=1.0)
        Sr = ref.project_poly_ref(Th, Phi, 1.0)
        np.testing.assert_allclose(S, Sr, rtol=2e-4, atol=1e-4)

    def test_se_is_special_case_of_poly(self):
        """theta=[c,a], phi=[1,T] reproduces ensemble_project with T0=-c/a."""
        a, T0, temps = rng_arrays(11, (10,), (10,), (14,))
        Th = jnp.stack([-a * T0, a], axis=-1)
        Phi = jnp.stack([jnp.ones_like(temps), temps], axis=-1)
        S_poly = k.ensemble_project_poly(Th, Phi)
        S_se = k.ensemble_project(a, T0, temps)
        np.testing.assert_allclose(S_poly, S_se, rtol=1e-4, atol=1e-4)

    def test_linearity_in_theta(self):
        Th, Phi = rng_arrays(13, (6, 3), (9, 3))
        S2 = k.ensemble_project_poly(2.0 * Th, Phi)
        S1 = k.ensemble_project_poly(Th, Phi)
        np.testing.assert_allclose(S2, 2.0 * S1, rtol=1e-4, atol=1e-4)


# ---------------------------------------------------------------------------
# block heuristics / VMEM estimates
# ---------------------------------------------------------------------------

class TestBlockHeuristics:
    @given(B=st.integers(1, 4096), T=st.integers(1, 512), K=st.integers(1, 8))
    @settings(max_examples=50, deadline=None)
    def test_gram_block_within_budget(self, B, T, K):
        bb = k.gram_block_b(B, T, K)
        assert 1 <= bb <= B
        assert k.gram_vmem_bytes(bb, T, K) <= 8 * 1024 * 1024

    @given(N=st.integers(1, 1 << 16), Y=st.integers(1, 512))
    @settings(max_examples=50, deadline=None)
    def test_project_block_lane_aligned(self, N, Y):
        bn = k.project_block_n(N, Y)
        assert bn % 8 == 0 or bn == N
        assert k.project_vmem_bytes(bn, Y) <= 16 * 1024 * 1024

    def test_flops_count(self):
        assert k.gram_mxu_flops(2, 10, 3) == 2 * (10 * 9 + 30)
