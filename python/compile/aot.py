"""AOT compile path: lower the FACTS steps to HLO text artifacts.

Emits one ``artifacts/<name>.hlo.txt`` per (step, size) variant plus an
``artifacts/manifest.json`` describing input/output shapes, which the Rust
runtime (``rust/src/runtime``) reads to bind PJRT executables.

Interchange format is HLO **text**, not a serialized HloModuleProto: jax
>= 0.5 emits protos with 64-bit instruction ids which xla_extension 0.5.1
(the version behind the published ``xla`` 0.1.6 crate) rejects
(``proto.id() <= INT_MAX``); the text parser reassigns ids and round-trips
cleanly. Lowered with ``return_tuple=True`` so the Rust side unwraps a
single tuple. See /opt/xla-example/README.md.

Usage:  cd python && python -m compile.aot --out-dir ../artifacts
"""

from __future__ import annotations

import argparse
import json
import os

import jax
import jax.numpy as jnp
from jax._src.lib import xla_client as xc

from compile import model as M

F32 = jnp.float32


def spec(*shape):
    return jax.ShapeDtypeStruct(shape, F32)


def to_hlo_text(lowered) -> str:
    """StableHLO -> XlaComputation -> HLO text (id-safe interchange)."""
    mlir_mod = lowered.compiler_ir("stablehlo")
    comp = xc._xla.mlir.mlir_module_to_xla_computation(
        str(mlir_mod), use_tuple_args=False, return_tuple=True
    )
    return comp.as_hlo_text()


# Size variants exercised by the Rust side. "small" gates tests and the
# quickstart; "default" is the Experiment-4 workload; "large" stresses the
# projection ensemble (N = B * M = 1024 members).
SIZES = {
    "small": dict(B=4, T=32, M=8, Y=32),
    "default": dict(B=16, T=128, M=16, Y=96),
    "large": dict(B=16, T=128, M=64, Y=96),
}
Q = len(M.QUANTILES)


def variants():
    """Yield (name, fn, [input specs], [output names])."""
    for size, d in SIZES.items():
        B, T, Mm, Y = d["B"], d["T"], d["M"], d["Y"]
        yield (f"preprocess_{size}",
               M.facts_preprocess,
               [spec(B, T), spec(B, T)],
               ["X4", "X2", "y", "tref"])
        for K in (2, 4):
            yield (f"fit_k{K}_{size}",
                   M.facts_fit,
                   [spec(B, T, K), spec(B, T)],
                   ["theta", "sigma2", "A"])
        yield (f"project_se_{size}",
               M.facts_project_se,
               [spec(B, 2), spec(B), spec(B, 2, 2), spec(B, Mm, 2), spec(Y)],
               ["quants", "mean"])
        yield (f"project_poly_{size}",
               M.facts_project_poly,
               [spec(B, 4), spec(B), spec(B, 4, 4), spec(B, Mm, 4), spec(Y, 4)],
               ["quants", "mean"])
        yield (f"postprocess_{size}",
               M.facts_postprocess,
               [spec(2, Q, Y), spec(2)],
               ["combined", "envelope", "total_rise"])


def main() -> None:
    p = argparse.ArgumentParser()
    p.add_argument("--out-dir", default="../artifacts")
    p.add_argument("--only", default=None, help="substring filter on names")
    args = p.parse_args()
    os.makedirs(args.out_dir, exist_ok=True)

    manifest = {"format": "hlo-text-v1", "quantiles": list(M.QUANTILES),
                "artifacts": []}
    for name, fn, in_specs, out_names in variants():
        if args.only and args.only not in name:
            continue
        lowered = jax.jit(fn).lower(*in_specs)
        text = to_hlo_text(lowered)
        fname = f"{name}.hlo.txt"
        with open(os.path.join(args.out_dir, fname), "w") as f:
            f.write(text)
        outs = jax.eval_shape(fn, *in_specs)
        outs = jax.tree_util.tree_leaves(outs)
        manifest["artifacts"].append({
            "name": name,
            "file": fname,
            "inputs": [{"name": f"in{i}", "shape": list(s.shape),
                        "dtype": "f32"} for i, s in enumerate(in_specs)],
            "outputs": [{"name": n, "shape": list(o.shape), "dtype": "f32"}
                        for n, o in zip(out_names, outs)],
        })
        print(f"wrote {fname}: {len(text)} chars, "
              f"{len(in_specs)} in / {len(outs)} out")
    with open(os.path.join(args.out_dir, "manifest.json"), "w") as f:
        json.dump(manifest, f, indent=1)
    print(f"manifest: {len(manifest['artifacts'])} artifacts -> {args.out_dir}")


if __name__ == "__main__":
    main()
