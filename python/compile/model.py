"""L2: the FACTS compute graph in JAX, calling the L1 Pallas kernels.

One FACTS workflow instance (paper SS4, Experiment 4) is a four-step DAG:

    pre-processing -> fitting -> projecting -> post-processing

Each step below is a pure JAX function over fixed shapes, AOT-lowered by
``aot.py`` to HLO text and executed from the Rust coordinator via PJRT --
Python never runs on the request path.

Science model (see kernels/ref.py): a semi-empirical sea-level response
   dS/dt = a (T - T0)           ("se"  module, K=2 regression)
and a polynomial emulator
   dS/dt = theta . [1, Tn, Tn^2, tau]  ("poly" module, K=4 regression)
fit by ridge least squares on a historical (temperature, sea-level-rate)
record, then projected by Monte-Carlo sampling of the posterior
   theta_n = theta_hat + sigma * L^-T eps_n,   A = G + lam I = L L^T
over a future temperature scenario, reporting IPCC-style quantiles.

All linear algebra is unrolled (Cholesky / triangular solves over small K)
so the lowered HLO contains no LAPACK custom-calls: the Rust CPU PJRT
client can only execute core HLO ops.
"""

from __future__ import annotations

import jax
import jax.numpy as jnp

from compile.kernels import sealevel as kernels

# IPCC-style reporting quantiles (median + likely + very-likely ranges).
QUANTILES = (0.05, 0.17, 0.5, 0.83, 0.95)
# Ridge regularizer: keeps A = G + lam I SPD even for degenerate records.
RIDGE_LAM = 1e-3
# Reference window (steps) for the temperature-anomaly baseline.
REF_WINDOW = 20


# ---------------------------------------------------------------------------
# Step 1: pre-processing
# ---------------------------------------------------------------------------

def facts_preprocess(temps: jnp.ndarray, rates: jnp.ndarray):
    """Build regression features from raw historical records.

    Args:
      temps: (B, T) raw temperature series per site/scenario.
      rates: (B, T) raw sea-level-rate series (mm/yr).

    Returns:
      X4: (B, T, 4) poly design matrices [1, Tn, Tn^2, tau].
      X2: (B, T, 2) semi-empirical design matrices [1, Tn].
      y:  (B, T) rates, baseline-removed.
      tref: (B,) per-site reference temperature.
    """
    B, T = temps.shape
    w = min(REF_WINDOW, T)
    tref = jnp.mean(temps[:, :w], axis=1)
    tn = temps - tref[:, None]
    tau = jnp.broadcast_to(jnp.linspace(0.0, 1.0, T, dtype=temps.dtype), (B, T))
    ones = jnp.ones_like(tn)
    X4 = jnp.stack([ones, tn, tn * tn, tau], axis=-1)
    X2 = jnp.stack([ones, tn], axis=-1)
    y = rates - jnp.mean(rates[:, :w], axis=1, keepdims=True) * 0.0  # keep raw rates
    return X4, X2, y, tref


# ---------------------------------------------------------------------------
# Small unrolled linear algebra (no LAPACK custom-calls)
# ---------------------------------------------------------------------------

def _chol_unrolled(A: jnp.ndarray):
    """Cholesky of (..., K, K) SPD matrices, unrolled at trace time.

    Returns the lower factor as a K x K nested list of (...,)-shaped arrays.
    """
    K = A.shape[-1]
    L = [[None] * K for _ in range(K)]
    for i in range(K):
        for j in range(i + 1):
            s = A[..., i, j]
            for p in range(j):
                s = s - L[i][p] * L[j][p]
            if i == j:
                L[i][j] = jnp.sqrt(jnp.maximum(s, 1e-30))
            else:
                L[i][j] = s / L[j][j]
    return L


def _solve_chol(L, m: jnp.ndarray):
    """Solve L L^T theta = m; m: (..., K) -> theta (..., K)."""
    K = len(L)
    z = [None] * K
    for i in range(K):
        s = m[..., i]
        for p in range(i):
            s = s - L[i][p] * z[p]
        z[i] = s / L[i][i]
    th = [None] * K
    for i in reversed(range(K)):
        s = z[i]
        for p in range(i + 1, K):
            s = s - L[p][i] * th[p]
        th[i] = s / L[i][i]
    return jnp.stack(th, axis=-1)


def _solve_lt(L, e: jnp.ndarray):
    """Solve L^T x = e for posterior sampling; e: (..., M, K) with L (...,)-shaped
    entries broadcast over M. Returns (..., M, K)."""
    K = len(L)
    x = [None] * K
    for i in reversed(range(K)):
        s = e[..., i]
        for p in range(i + 1, K):
            s = s - L[p][i][..., None] * x[p]
        x[i] = s / L[i][i][..., None]
    return jnp.stack(x, axis=-1)


# ---------------------------------------------------------------------------
# Step 2: fitting
# ---------------------------------------------------------------------------

def facts_fit(X: jnp.ndarray, y: jnp.ndarray):
    """Ridge least-squares fit via the Pallas batched-Gram kernel.

    Args:
      X: (B, T, K) design matrices.
      y: (B, T) targets.

    Returns:
      theta:  (B, K) coefficients.
      sigma2: (B,) residual variances.
      A:      (B, K, K) regularized Gram matrices (posterior precision / sigma2).
    """
    B, T, K = X.shape
    G, m = kernels.batched_gram(X, y)
    A = G + RIDGE_LAM * jnp.eye(K, dtype=G.dtype)[None, :, :]
    L = _chol_unrolled(A)
    theta = _solve_chol(L, m)
    resid = y - jnp.einsum("btk,bk->bt", X, theta)
    dof = max(T - K, 1)
    sigma2 = jnp.sum(resid * resid, axis=1) / dof
    return theta, sigma2, A


# ---------------------------------------------------------------------------
# Step 3: projecting
# ---------------------------------------------------------------------------

def _sample_thetas(theta, sigma2, A, eps):
    """Posterior samples theta_n = theta + sigma L^-T eps_n.

    theta: (B, K), sigma2: (B,), A: (B, K, K), eps: (B, M, K)
    -> (B, M, K)
    """
    L = _chol_unrolled(A)
    d = _solve_lt(L, eps)                        # (B, M, K)
    return theta[:, None, :] + jnp.sqrt(sigma2)[:, None, None] * d


def facts_project_se(theta, sigma2, A, eps, temps_fut, *, dt: float = 1.0):
    """Semi-empirical projection: dS/dt = a (T - T0).

    Args:
      theta: (B, 2) fitted [c, a] with rate = c + a*Tn, i.e. T0 = -c/a.
      sigma2, A, eps: posterior pieces; eps: (B, M, 2).
      temps_fut: (Y,) future temperature anomaly scenario.

    Returns:
      quants: (Q, Y) ensemble quantiles, mean: (Y,), samples mean trajectory.
    """
    B, M, _ = eps.shape
    th = _sample_thetas(theta, sigma2, A, eps)    # (B, M, 2)
    c = th[..., 0].reshape(-1)                    # (B*M,)
    a = th[..., 1].reshape(-1)
    # Guard: |a| bounded away from 0 so T0 = -c/a stays finite.
    a = jnp.where(jnp.abs(a) < 1e-6, 1e-6, a)
    T0 = -c / a
    S = kernels.ensemble_project(a, T0, temps_fut, dt=dt)   # (B*M, Y)
    qs = jnp.quantile(S, jnp.array(QUANTILES, dtype=S.dtype), axis=0)
    return qs, jnp.mean(S, axis=0)


def facts_project_poly(theta, sigma2, A, eps, phi_fut, *, dt: float = 1.0):
    """Polynomial-emulator projection: dS/dt = theta . phi(t).

    Args:
      theta: (B, 4), sigma2: (B,), A: (B, 4, 4), eps: (B, M, 4).
      phi_fut: (Y, 4) feature rows of the future scenario.

    Returns:
      quants: (Q, Y), mean: (Y,).
    """
    B, M, K = eps.shape
    th = _sample_thetas(theta, sigma2, A, eps).reshape(B * M, K)
    S = kernels.ensemble_project_poly(th, phi_fut, dt=dt)   # (B*M, Y)
    qs = jnp.quantile(S, jnp.array(QUANTILES, dtype=S.dtype), axis=0)
    return qs, jnp.mean(S, axis=0)


# ---------------------------------------------------------------------------
# Step 4: post-processing
# ---------------------------------------------------------------------------

def facts_postprocess(quants: jnp.ndarray, weights: jnp.ndarray):
    """Combine per-module quantile fans into a single assessment.

    Args:
      quants: (MODS, Q, Y) per-module quantiles.
      weights: (MODS,) module weights (renormalized here).

    Returns:
      combined: (Q, Y) weighted quantile fan.
      envelope: (2, Y) min/max across modules of the outer quantiles.
      total_rise: () weighted median rise at the horizon.
    """
    w = weights / jnp.maximum(jnp.sum(weights), 1e-12)
    combined = jnp.einsum("m,mqy->qy", w, quants)
    lo = jnp.min(quants[:, 0, :], axis=0)
    hi = jnp.max(quants[:, -1, :], axis=0)
    envelope = jnp.stack([lo, hi], axis=0)
    total_rise = combined[combined.shape[0] // 2, -1]
    return combined, envelope, total_rise
