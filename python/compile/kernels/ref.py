"""Pure-jnp reference oracles for the FACTS compute kernels.

These are the correctness ground truth for the Pallas kernels in
``sealevel.py``. They are deliberately written in the most obvious
vectorized-jnp style (no tiling, no pallas) so that any divergence in the
kernels is attributable to the kernel implementation, not the oracle.

The science model is a semi-empirical sea-level response model
(Rahmstorf-type):

    dS/dt = a * (T(t) - T0)

fit against a historical (temperature, sea-level-rate) record via ridge
least squares, and projected forward by Monte-Carlo sampling of the fitted
parameters over future temperature scenarios. This is the mathematical core
of the FACTS modules the paper runs in Experiment 4 (pre-processing,
fitting, projecting, post-processing).
"""

from __future__ import annotations

import jax.numpy as jnp


def gram_ref(X: jnp.ndarray, y: jnp.ndarray):
    """Batched Gram matrices and moment vectors.

    Args:
      X: (B, T, K) batch of design matrices.
      y: (B, T) batch of targets.

    Returns:
      G: (B, K, K) with G[b] = X[b]^T X[b]
      m: (B, K)    with m[b] = X[b]^T y[b]
    """
    G = jnp.einsum("btk,btl->bkl", X, X)
    m = jnp.einsum("btk,bt->bk", X, y)
    return G, m


def cholesky_solve_small_ref(G: jnp.ndarray, m: jnp.ndarray, lam: float):
    """Solve (G + lam*I) theta = m for small K via explicit Cholesky.

    Unrolled over K at trace time: only matmul/elementwise/sqrt ops, so the
    lowered HLO contains no LAPACK custom-calls (the rust CPU PJRT client
    cannot resolve those).

    Args:
      G: (B, K, K) SPD matrices.
      m: (B, K).
      lam: ridge regularizer.

    Returns:
      theta: (B, K)
    """
    B, K, _ = G.shape
    A = G + lam * jnp.eye(K, dtype=G.dtype)[None, :, :]
    # Cholesky: A = L L^T, unrolled at trace time.
    L = [[None] * K for _ in range(K)]
    for i in range(K):
        for j in range(i + 1):
            s = A[:, i, j]
            for p in range(j):
                s = s - L[i][p] * L[j][p]
            if i == j:
                L[i][j] = jnp.sqrt(jnp.maximum(s, 1e-30))
            else:
                L[i][j] = s / L[j][j]
    # Forward substitution: L z = m
    z = [None] * K
    for i in range(K):
        s = m[:, i]
        for p in range(i):
            s = s - L[i][p] * z[p]
        z[i] = s / L[i][i]
    # Back substitution: L^T theta = z
    th = [None] * K
    for i in reversed(range(K)):
        s = z[i]
        for p in range(i + 1, K):
            s = s - L[p][i] * th[p]
        th[i] = s / L[i][i]
    return jnp.stack(th, axis=1)


def project_ref(a: jnp.ndarray, T0: jnp.ndarray, temps: jnp.ndarray, dt: float):
    """Ensemble sea-level projection.

    S[n, y] = a[n] * sum_{t <= y} (temps[t] - T0[n]) * dt

    Args:
      a:     (N,) ensemble of sensitivity parameters (mm / yr / K).
      T0:    (N,) ensemble of equilibrium temperatures (K anomaly).
      temps: (Y,) future temperature scenario (K anomaly per year).
      dt:    timestep in years.

    Returns:
      S: (N, Y) sea-level anomaly trajectories (mm).
    """
    drive = temps[None, :] - T0[:, None]          # (N, Y)
    return a[:, None] * jnp.cumsum(drive, axis=1) * dt


def quantiles_ref(S: jnp.ndarray, qs: jnp.ndarray):
    """Per-year ensemble quantiles. S: (N, Y), qs: (Q,) -> (Q, Y)."""
    return jnp.quantile(S, qs, axis=0)


def standardize_ref(x: jnp.ndarray):
    """Column standardization used by the pre-processing step.

    x: (T, K) -> (x - mean) / std, plus the (mean, std) used.
    """
    mu = jnp.mean(x, axis=0)
    sd = jnp.std(x, axis=0)
    sd = jnp.where(sd < 1e-12, 1.0, sd)
    return (x - mu) / sd, mu, sd


def project_poly_ref(Theta: jnp.ndarray, Phi: jnp.ndarray, dt: float):
    """Polynomial-emulator projection oracle.

    S[n, y] = dt * sum_{t <= y} Theta[n] . Phi[t]

    Theta: (N, K), Phi: (Y, K) -> (N, Y).
    """
    rate = Theta @ Phi.T                          # (N, Y)
    return jnp.cumsum(rate, axis=1) * dt
