"""L1 Pallas kernels for the FACTS sea-level compute.

Two kernels cover the hot path of the FACTS workflow steps brokered by
Hydra in Experiment 4:

* ``batched_gram``    -- fitting: per-batch Gram matrices G = X^T X and
                         moments m = X^T y (MXU-shaped batched matmul).
* ``ensemble_project``-- projecting: Monte-Carlo ensemble integration of
                         dS/dt = a (T - T0) (VPU-shaped rowwise scan).

TPU design notes (see DESIGN.md `Hardware-Adaptation`):

* The paper's platforms are CPU clouds, so there is no CUDA kernel to port;
  we instead map the science hot-spot onto TPU idioms. ``batched_gram``
  blocks over the batch dimension and keeps each (T, K) design-matrix tile
  resident in VMEM, contracting over T on the MXU. ``ensemble_project``
  blocks over ensemble members -- rows map onto VPU lanes -- and carries the
  year-prefix sum inside the block (Y fits VMEM comfortably for centennial
  projections).
* Kernels are lowered with ``interpret=True``: the CPU PJRT plugin cannot
  execute Mosaic custom-calls, so interpret mode is the correctness path and
  real-TPU performance is *estimated* from the BlockSpec footprint (see
  ``gram_vmem_bytes`` / ``project_vmem_bytes`` and EXPERIMENTS.md `Perf`).

Correctness oracle: ``ref.py`` (pure jnp), compared by
``python/tests/test_kernels.py`` under hypothesis shape sweeps.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl


# ---------------------------------------------------------------------------
# Block-size heuristics
# ---------------------------------------------------------------------------

def _round_up(x: int, m: int) -> int:
    return ((x + m - 1) // m) * m


def gram_block_b(B: int, T: int, K: int) -> int:
    """Pick the batch block for ``batched_gram``.

    Keep the VMEM working set (X block + outputs) under ~4 MiB so two
    grid steps can double-buffer within a 16 MiB VMEM budget.
    """
    budget = 4 * 1024 * 1024
    per_b = 4 * (T * K + K * K + K + T)  # f32 bytes per batch member
    bb = max(1, budget // max(per_b, 1))
    return int(min(bb, B))


def project_block_n(N: int, Y: int) -> int:
    """Pick the ensemble block for ``ensemble_project`` (~4 MiB budget)."""
    budget = 4 * 1024 * 1024
    per_n = 4 * (2 * Y + 2)  # drive + out rows + a + T0, f32
    bn = max(1, budget // max(per_n, 1))
    # Lane-align the block: VPU rows come in multiples of 8.
    bn = max(8, (bn // 8) * 8)
    return int(min(bn, _round_up(N, 8)))


def gram_vmem_bytes(BB: int, T: int, K: int) -> int:
    """Estimated VMEM footprint of one ``batched_gram`` grid step (bytes)."""
    return 4 * BB * (T * K + T + K * K + K)


def project_vmem_bytes(BN: int, Y: int) -> int:
    """Estimated VMEM footprint of one ``ensemble_project`` grid step."""
    return 4 * (BN * Y * 2 + 2 * BN + Y)


def gram_mxu_flops(B: int, T: int, K: int) -> int:
    """MAC count of the Gram contraction (for the `Perf` roofline estimate)."""
    return B * (T * K * K + T * K)


# ---------------------------------------------------------------------------
# batched_gram
# ---------------------------------------------------------------------------

def _gram_kernel(x_ref, y_ref, g_ref, m_ref):
    """One grid step: Gram + moments for a (BB, T, K) block of fits.

    The contraction over T is a batched matmul -> MXU. Accumulate in f32
    regardless of input dtype (bf16 inputs still get f32 accumulation, the
    MXU-native mode).
    """
    x = x_ref[...].astype(jnp.float32)   # (BB, T, K)
    y = y_ref[...].astype(jnp.float32)   # (BB, T)
    # G[b] = X[b]^T X[b] : contract over T (dim 1) batched over dim 0.
    g_ref[...] = jax.lax.dot_general(
        x, x, dimension_numbers=(((1,), (1,)), ((0,), (0,))),
        preferred_element_type=jnp.float32)
    # m[b] = X[b]^T y[b]
    m_ref[...] = jax.lax.dot_general(
        x, y, dimension_numbers=(((1,), (1,)), ((0,), (0,))),
        preferred_element_type=jnp.float32)


@functools.partial(jax.jit, static_argnames=("block_b",))
def batched_gram(X: jnp.ndarray, y: jnp.ndarray, *, block_b: int | None = None):
    """Batched Gram matrices via Pallas.

    Args:
      X: (B, T, K) design matrices.
      y: (B, T) targets.
      block_b: optional batch block override (default: heuristic).

    Returns:
      (G, m): (B, K, K), (B, K) float32.
    """
    B, T, K = X.shape
    bb = block_b or gram_block_b(B, T, K)
    Bp = _round_up(B, bb)
    if Bp != B:
        X = jnp.pad(X, ((0, Bp - B), (0, 0), (0, 0)))
        y = jnp.pad(y, ((0, Bp - B), (0, 0)))
    grid = (Bp // bb,)
    G, m = pl.pallas_call(
        _gram_kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec((bb, T, K), lambda i: (i, 0, 0)),
            pl.BlockSpec((bb, T), lambda i: (i, 0)),
        ],
        out_specs=[
            pl.BlockSpec((bb, K, K), lambda i: (i, 0, 0)),
            pl.BlockSpec((bb, K), lambda i: (i, 0)),
        ],
        out_shape=[
            jax.ShapeDtypeStruct((Bp, K, K), jnp.float32),
            jax.ShapeDtypeStruct((Bp, K), jnp.float32),
        ],
        interpret=True,
    )(X, y)
    return G[:B], m[:B]


# ---------------------------------------------------------------------------
# ensemble_project
# ---------------------------------------------------------------------------

def _project_kernel(a_ref, t0_ref, temps_ref, o_ref, *, dt: float):
    """One grid step: (BN, Y) trajectories for a block of ensemble members.

    cumsum(T[t] - T0) decomposes as cumsum(T)[t] - (t+1) * T0; we keep the
    direct form -- the (BN, Y) drive block lives in VMEM and the prefix sum
    runs along the minor (lane) axis.
    """
    a = a_ref[...].astype(jnp.float32)          # (BN,)
    t0 = t0_ref[...].astype(jnp.float32)        # (BN,)
    temps = temps_ref[...].astype(jnp.float32)  # (Y,)
    drive = temps[None, :] - t0[:, None]        # (BN, Y)
    o_ref[...] = a[:, None] * jnp.cumsum(drive, axis=1) * dt


@functools.partial(jax.jit, static_argnames=("dt", "block_n"))
def ensemble_project(a: jnp.ndarray, T0: jnp.ndarray, temps: jnp.ndarray,
                     *, dt: float = 1.0, block_n: int | None = None):
    """Monte-Carlo ensemble projection via Pallas.

    Args:
      a:     (N,) sensitivity samples.
      T0:    (N,) equilibrium-temperature samples.
      temps: (Y,) future temperature scenario.
      dt:    years per step (static).
      block_n: optional ensemble block override.

    Returns:
      S: (N, Y) float32 trajectories.
    """
    N = a.shape[0]
    (Y,) = temps.shape
    bn = block_n or project_block_n(N, Y)
    Np = _round_up(N, bn)
    if Np != N:
        a = jnp.pad(a, (0, Np - N))
        T0 = jnp.pad(T0, (0, Np - N))
    grid = (Np // bn,)
    S = pl.pallas_call(
        functools.partial(_project_kernel, dt=float(dt)),
        grid=grid,
        in_specs=[
            pl.BlockSpec((bn,), lambda i: (i,)),
            pl.BlockSpec((bn,), lambda i: (i,)),
            pl.BlockSpec((Y,), lambda i: (0,)),
        ],
        out_specs=pl.BlockSpec((bn, Y), lambda i: (i, 0)),
        out_shape=jax.ShapeDtypeStruct((Np, Y), jnp.float32),
        interpret=True,
    )(a, T0, temps)
    return S[:N]


# ---------------------------------------------------------------------------
# ensemble_project_poly
# ---------------------------------------------------------------------------

def _project_poly_kernel(theta_ref, phi_ref, o_ref, *, dt: float):
    """One grid step: trajectories for a (BN, K) block of sampled coefficients.

    rate = Theta @ Phi^T is an (BN, K) x (K, Y) matmul -> MXU; the prefix sum
    over years then runs on the VPU along the lane axis.
    """
    theta = theta_ref[...].astype(jnp.float32)  # (BN, K)
    phi = phi_ref[...].astype(jnp.float32)      # (Y, K)
    rate = jax.lax.dot_general(
        theta, phi, dimension_numbers=(((1,), (1,)), ((), ())),
        preferred_element_type=jnp.float32)     # (BN, Y)
    o_ref[...] = jnp.cumsum(rate, axis=1) * dt


@functools.partial(jax.jit, static_argnames=("dt", "block_n"))
def ensemble_project_poly(Theta: jnp.ndarray, Phi: jnp.ndarray,
                          *, dt: float = 1.0, block_n: int | None = None):
    """Polynomial-emulator ensemble projection via Pallas.

    S[n, y] = dt * sum_{t <= y} Theta[n] . Phi[t]

    Args:
      Theta: (N, K) sampled regression coefficients.
      Phi:   (Y, K) feature rows of the future scenario.
      dt:    years per step (static).

    Returns:
      S: (N, Y) float32 trajectories.
    """
    N, K = Theta.shape
    Y, K2 = Phi.shape
    assert K == K2, f"feature mismatch {K} vs {K2}"
    bn = block_n or project_block_n(N, Y)
    Np = _round_up(N, bn)
    if Np != N:
        Theta = jnp.pad(Theta, ((0, Np - N), (0, 0)))
    grid = (Np // bn,)
    S = pl.pallas_call(
        functools.partial(_project_poly_kernel, dt=float(dt)),
        grid=grid,
        in_specs=[
            pl.BlockSpec((bn, K), lambda i: (i, 0)),
            pl.BlockSpec((Y, K), lambda i: (0, 0)),
        ],
        out_specs=pl.BlockSpec((bn, Y), lambda i: (i, 0)),
        out_shape=jax.ShapeDtypeStruct((Np, Y), jnp.float32),
        interpret=True,
    )(Theta, Phi)
    return S[:N]
